"""Genome encoding/decoding (SparseMap §IV.B, §IV.C, §IV.F, Fig. 13).

Genome layout (1-D int array), for a workload with ``d`` iteration dims,
``n_primes`` prime-factor slots, and an arch with ``n_levels`` mapping
levels and ``n_sites`` S/G sites (default paper arch: 5 levels, 3 sites):

    [ perm x n_levels | tiling_1..tiling_n | P fmt x5 | Q fmt x5
      | Z fmt x5 | SG x n_sites ]

* **Permutations** — Cantor (Lehmer) encoding, one gene per mapping level,
  value in [0, d!-1]; adjacent codes are adjacent permutations with the
  outer-loop rank dominating (paper Eq. 1, Fig. 10).
* **Dim. tiling** — prime-factor encoding: gene i holds the mapping level
  (in [0, n_levels)) that prime factor i of the concatenated dimension
  factorization is assigned to.  Every genome therefore satisfies the
  dimension-tiling constraint *by construction* (paper: direct value
  encoding leaves only 0.000023 % of the space valid).
* **Formats** — 5 genes per tensor in [0,4] (U/B/RLE/CP/UOP); the last k
  genes map to the k tiled sub-dimensions (cost_model.make_tensor_format).
* **S/G** — one gene in [0,6] per arch S/G site (store sites then
  compute; paper arch: GLB / PE buffer / compute).

The layout depends only on the arch's *mapping-level and site structure*
and the workload's *dimension structure*: per-level word widths and NoC
descriptors reprice the cost model but add no genes, and the same holds
for per-tensor density models (``repro.core.density``) — a uniform, a
banded and a 2:4-pruned workload of the same shape share identical
genome layouts (density models reprice occupancy/intersections via the
kernel's traced parameter rows, they never widen the genome).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .arch import ARCH_SPARSEMAP, ArchSpec
from .cost_model import Design, make_tensor_format
from .mapping import Mapping
from .sparse import MAX_FMT_GENES, N_SG, SparseStrategy
from .workload import Workload

# ---------------------------------------------------------------- cantor


def cantor_encode(perm: Sequence[int]) -> int:
    """Lehmer-code a permutation of range(d) to an int in [0, d!-1].
    The paper's Eq. (1) is this +1 (1-based); we keep 0-based genes."""
    d = len(perm)
    code = 0
    for i in range(d):
        rank = sum(1 for j in range(i + 1, d) if perm[j] < perm[i])
        code += rank * math.factorial(d - 1 - i)
    return code


def cantor_decode(code: int, d: int) -> Tuple[int, ...]:
    """Inverse of :func:`cantor_encode`."""
    avail = list(range(d))
    out = []
    for i in range(d):
        f = math.factorial(d - 1 - i)
        idx, code = divmod(code, f)
        out.append(avail.pop(idx))
    return tuple(out)


def all_permutations(d: int) -> np.ndarray:
    """Lookup table: row c = cantor_decode(c, d).  Shape (d!, d)."""
    return np.array([cantor_decode(c, d) for c in range(math.factorial(d))],
                    dtype=np.int32)


# ---------------------------------------------------------------- genome


@dataclasses.dataclass(frozen=True)
class Segment:
    name: str
    start: int
    stop: int

    @property
    def slice(self) -> slice:
        return slice(self.start, self.stop)

    def __len__(self) -> int:
        return self.stop - self.start


class GenomeSpec:
    """Genome layout + decode for one (workload, arch).  All searches (ES
    and every baseline) operate on this representation; the layout is
    derived from the arch's mapping-level and S/G-site structure."""

    def __init__(self, workload: Workload, arch: ArchSpec = ARCH_SPARSEMAP):
        self.workload = workload
        self.arch = arch
        self.d = workload.ndims
        self.n_perm_codes = math.factorial(self.d)
        self.primes = workload.prime_factors          # [(dim, p), ...]
        self.n_primes = len(self.primes)
        self.tensor_names = [t.name for t in workload.tensors]

        segs: List[Segment] = []
        pos = 0

        def add(name: str, n: int):
            nonlocal pos
            segs.append(Segment(name, pos, pos + n))
            pos += n

        add("perm", arch.n_levels)
        add("tiling", self.n_primes)
        for tn in self.tensor_names:
            add(f"fmt_{tn}", MAX_FMT_GENES)
        add("sg", len(arch.sg_sites))
        self.segments = {s.name: s for s in segs}
        self.length = pos

        # per-gene upper bounds (exclusive)
        ub = np.empty(self.length, dtype=np.int64)
        ub[self.segments["perm"].slice] = self.n_perm_codes
        ub[self.segments["tiling"].slice] = arch.n_levels
        for tn in self.tensor_names:
            ub[self.segments[f"fmt_{tn}"].slice] = 5
        ub[self.segments["sg"].slice] = N_SG
        self.gene_ub = ub
        self._gene_ub_minus1 = ub - 1
        self._gene_ub_f64 = ub.astype(np.float64)[None, :]
        self._perm_table = all_permutations(self.d)

    # ------------------------------------------------------------ decode
    def decode_mapping(self, genome: np.ndarray) -> Mapping:
        wl = self.workload
        perm_genes = genome[self.segments["perm"].slice]
        tiling_genes = genome[self.segments["tiling"].slice]
        factors: List[Dict[str, int]] = [dict()
                                         for _ in range(self.arch.n_levels)]
        for (dim, p), lvl in zip(self.primes, tiling_genes):
            lvl = int(lvl)
            factors[lvl][dim] = factors[lvl].get(dim, 1) * p
        perms = tuple(
            tuple(wl.dim_order[i] for i in self._perm_table[int(c)])
            for c in perm_genes)
        return Mapping(workload=wl, factors=tuple(factors), perms=perms,
                       arch=self.arch)

    def decode(self, genome: np.ndarray) -> Design:
        genome = np.asarray(genome)
        if genome.shape != (self.length,):
            raise ValueError(f"genome shape {genome.shape} != ({self.length},)")
        if (genome < 0).any() or (genome >= self.gene_ub).any():
            raise ValueError("gene out of range")
        mp = self.decode_mapping(genome)
        fmts = {}
        for tn in self.tensor_names:
            genes = tuple(int(g) for g in
                          genome[self.segments[f"fmt_{tn}"].slice])
            fmts[tn] = make_tensor_format(mp, tn, genes)
        sg = {site: int(g) for site, g in
              zip(self.arch.sg_sites, genome[self.segments["sg"].slice])}
        return Design(mapping=mp, strategy=SparseStrategy(formats=fmts, sg=sg))

    # ------------------------------------------------------------ encode
    def encode_mapping(self, mapping: Mapping) -> np.ndarray:
        """Inverse of decode for the mapping genes (tiling assignment is
        reconstructed greedily: primes of each dim are assigned outer-level
        first to reproduce the factor products)."""
        wl = self.workload
        nl = self.arch.n_levels
        genome = np.zeros(self.length, dtype=np.int64)
        inv_dim = {d: i for i, d in enumerate(wl.dim_order)}
        for lvl in range(nl):
            perm_idx = tuple(inv_dim[d] for d in mapping.perms[lvl])
            genome[self.segments["perm"].start + lvl] = cantor_encode(perm_idx)
        # greedy prime reassembly: walk primes in order, consume levels
        tpos = self.segments["tiling"].start
        remaining = {d: [mapping.factors[l].get(d, 1) for l in range(nl)]
                     for d in wl.dim_order}
        for i, (dim, p) in enumerate(self.primes):
            for lvl in range(nl):
                if remaining[dim][lvl] % p == 0 and remaining[dim][lvl] > 1:
                    remaining[dim][lvl] //= p
                    genome[tpos + i] = lvl
                    break
            else:
                raise ValueError(f"cannot reassemble tiling for {dim} prime {p}")
        return genome

    # ------------------------------------------------------------ sampling
    def random_genomes(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """One vectorized draw for the whole (n, L) population.  The
        multiply-and-floor formulation consumes exactly n*L uniforms, so
        seeded streams stay reproducible across code paths."""
        return (rng.random((n, self.length)) *
                self._gene_ub_f64).astype(np.int64)

    def clip(self, genomes: np.ndarray) -> np.ndarray:
        """Clamp genes into range.  Always returns a fresh array (callers
        mutate the result in place); the bound array is precomputed."""
        return np.clip(genomes, 0, self._gene_ub_minus1[None, :])

    # segment boundaries, used by sensitivity-aware crossover
    def segment_bounds(self) -> List[int]:
        bounds = sorted({s.start for s in self.segments.values()} |
                        {self.length})
        return bounds
