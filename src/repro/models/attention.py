"""Attention: GQA with causal / sliding-window masks, query-chunked
computation for long sequences (bounded O(chunk*S) score memory — the
pure-jnp stand-in for the Pallas flash kernel, same blocking scheme), and
single-token decode against a KV cache.

All functions are pjit-friendly: no explicit collectives; sharding is
induced by the in/out shardings and `with_sharding_constraint` at the
model level.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """[B,S,KV,hd] -> [B,S,KV*n_rep,hd] by head repetition (GQA)."""
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.repeat(k, n_rep, axis=2)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, window: Optional[int] = None,
              q_offset: int = 0, chunk: int = 0) -> jnp.ndarray:
    """q: [B,Sq,H,hd], k/v: [B,Sk,KV,hd] -> [B,Sq,H,hd].

    ``window``: sliding-window size (None = full).  ``q_offset``: absolute
    position of q[0] relative to k[0] (prefill continuation / decode).
    ``chunk`` > 0: compute in query chunks of that size (flash-style row
    blocking) so the materialized score block is [B,H,chunk,Sk].
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    n_rep = h // kv
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)

    if chunk and sq > chunk and sq % chunk == 0:
        n_chunks = sq // chunk
        qc = q.reshape(b, n_chunks, chunk, h, hd)

        def one(carry, xs):
            qi, idx = xs
            off = q_offset + idx * chunk
            out = _attn_block(qi, k, v, causal, window, off)
            return carry, out

        _, outs = jax.lax.scan(
            one, None,
            (qc.transpose(1, 0, 2, 3, 4),
             jnp.arange(n_chunks)))
        return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)
    return _attn_block(q, k, v, causal, window, q_offset)


def _attn_block(q, k, v, causal, window, q_offset):
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    # [B,H,Sq,Sk]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(sq)[:, None]            # [Sq,1]
    kpos = jnp.arange(sk)[None, :]                       # [1,Sk]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, cache_len: jnp.ndarray,
                     window: Optional[int] = None) -> jnp.ndarray:
    """Single-position decode: q [B,1,H,hd] against cache [B,S,KV,hd].

    ``cache_len``: scalar int32 — number of valid cache positions (the new
    token's K/V must already be written at cache_len-1).
    """
    b, _, h, hd = q.shape
    s = k_cache.shape[1]
    kv = k_cache.shape[2]
    n_rep = h // kv
    k = _repeat_kv(k_cache, n_rep)
    v = _repeat_kv(v_cache, n_rep)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale    # [B,H,1,S]
    kpos = jnp.arange(s)[None, None, None, :]
    valid = kpos < cache_len
    if window is not None:
        valid &= kpos >= cache_len - window
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def update_cache(k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                 k_new: jnp.ndarray, v_new: jnp.ndarray,
                 cache_len: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Write k_new/v_new [B,1,KV,hd] at position cache_len."""
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), cache_len, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), cache_len, axis=1)
    return k_cache, v_cache
