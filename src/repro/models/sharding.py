"""Sharding context for model code.

The launcher declares the mesh batch axes once (e.g. ("data",) single-pod,
("pod", "data") multi-pod); model code then places
``with_sharding_constraint`` hints through :func:`constrain`.  When no axes
are declared (CPU smoke tests, single device) constraints are no-ops, so
the same model code runs everywhere.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_BATCH_AXES: Optional[Tuple[str, ...]] = None


def set_batch_axes(axes: Optional[Tuple[str, ...]]) -> None:
    global _BATCH_AXES
    _BATCH_AXES = tuple(axes) if axes is not None else None


def get_batch_axes() -> Optional[Tuple[str, ...]]:
    return _BATCH_AXES


@contextlib.contextmanager
def batch_axes(axes: Optional[Tuple[str, ...]]):
    global _BATCH_AXES
    prev = _BATCH_AXES
    _BATCH_AXES = tuple(axes) if axes is not None else None
    try:
        yield
    finally:
        _BATCH_AXES = prev


def bspec(*rest) -> P:
    """PartitionSpec with the batch axes leading: bspec(None, 'model')
    -> P(('pod','data'), None, 'model') on a multi-pod mesh.  Axis names
    already consumed by the batch axes are dropped from the tail (the
    pure-DP mapping folds 'model' into the batch)."""
    if _BATCH_AXES is None:
        return P()
    used = set(_BATCH_AXES)

    def clean(part):
        if part is None:
            return None
        parts = part if isinstance(part, tuple) else (part,)
        kept = tuple(a for a in parts if a not in used)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]

    lead = _BATCH_AXES if len(_BATCH_AXES) > 1 else _BATCH_AXES[0]
    return P(lead, *[clean(r) for r in rest])


def constrain(x, spec: P):
    if _BATCH_AXES is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_batch(x, *rest):
    if _BATCH_AXES is None:
        return x
    return jax.lax.with_sharding_constraint(x, bspec(*rest))
