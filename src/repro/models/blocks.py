"""Block param builders + apply functions for every block kind.

Each kind implements:
    build(cfg, key)                  -> (params, specs)   (one layer)
    train(cfg, p, x, off, enc_out)   -> (x, aux)
    cache_init(cfg, batch, max_len)  -> cache             (one layer)
    decode(cfg, p, cache, x_t, pos)  -> (x_t, cache)

Parameter sharding follows Megatron TP conventions on the "model" axis;
MoE experts are expert-parallel over "model" with the expert hidden dim
over "data" (FSDP-style); KV projections whose joint width is not
divisible by the TP degree stay replicated (GQA with few KV heads).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import attention as attn_lib
from . import ssm as ssm_lib
from .config import ModelConfig
from .layers import (apply_m_rope, apply_rope, dtype_of, mlp, rms_norm,
                     _init_dense)
from .moe import moe_ffn, moe_ffn_grouped, moe_params_shape
from .sharding import constrain_batch

TP = 16     # tensor-parallel degree of the production mesh ("model" axis)
_TP_ENABLED = True


def set_tp_enabled(flag: bool) -> None:
    """Disable tensor-parallel param sharding (pure-DP mapping for small
    models — §Perf hillclimb, xlstm train_4k)."""
    global _TP_ENABLED
    _TP_ENABLED = flag


def _split(key, n):
    return jax.random.split(key, n)


def _mdl(width: int) -> Optional[str]:
    """'model' if the width divides evenly across TP, else replicate."""
    if not _TP_ENABLED:
        return None
    return "model" if width % TP == 0 else None


# =========================================================== attention core


def _attn_params(cfg: ModelConfig, key, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = dtype_of(cfg.param_dtype)
    ks = _split(key, 4)
    p = dict(
        wq=_init_dense(ks[0], d, h * hd, dt),
        wk=_init_dense(ks[1], d, kv * hd, dt),
        wv=_init_dense(ks[2], d, kv * hd, dt),
        wo=_init_dense(ks[3], h * hd, d, dt),
    )
    s = dict(
        wq=P(None, _mdl(h * hd)),
        wk=P(None, _mdl(kv * hd)),
        wv=P(None, _mdl(kv * hd)),
        wo=P(_mdl(h * hd), None),
    )
    return p, s


def _qkv(cfg: ModelConfig, p, x, x_kv=None, positions=None):
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xk = x if x_kv is None else x_kv
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (xk @ p["wk"]).reshape(b, xk.shape[1], kv, hd)
    v = (xk @ p["wv"]).reshape(b, xk.shape[1], kv, hd)
    q = constrain_batch(q, None, "model", None)
    if positions is not None:
        if cfg.m_rope:
            q = apply_m_rope(q, positions, cfg.rope_theta)
            k = apply_m_rope(k, positions, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mlp_params(cfg: ModelConfig, key, d_ff: int):
    d = cfg.d_model
    dt = dtype_of(cfg.param_dtype)
    ks = _split(key, 3)
    p = dict(w1=_init_dense(ks[0], d, d_ff, dt),
             w2=_init_dense(ks[2], d_ff, d, dt))
    s = dict(w1=P(None, _mdl(d_ff)), w2=P(_mdl(d_ff), None))
    if cfg.mlp_kind == "swiglu":
        p["w3"] = _init_dense(ks[1], d, d_ff, dt)
        s["w3"] = P(None, _mdl(d_ff))
    return p, s


# =========================================================== attn block


def build_attn(cfg: ModelConfig, key, local: bool = False,
               cross: bool = False):
    ks = _split(key, 4)
    ap, asp = _attn_params(cfg, ks[0])
    mp, msp = _mlp_params(cfg, ks[1], cfg.d_ff)
    dt = dtype_of(cfg.param_dtype)
    p = dict(ln1=jnp.ones((cfg.d_model,), dt), attn=ap,
             ln2=jnp.ones((cfg.d_model,), dt), mlp=mp)
    s = dict(ln1=P(None), attn=asp, ln2=P(None), mlp=msp)
    if cross:
        cp, csp = _attn_params(cfg, ks[2])
        p["lnx"] = jnp.ones((cfg.d_model,), dt)
        p["xattn"] = cp
        s["lnx"] = P(None)
        s["xattn"] = csp
    return p, s


def train_attn(cfg: ModelConfig, p, x, off: int = 0, enc_out=None,
               local: bool = False, causal: bool = True):
    b, s, d = x.shape
    positions = off + jnp.arange(s)[None, :]
    q, k, v = _qkv(cfg, p["attn"], rms_norm(x, p["ln1"]),
                   positions=positions)
    window = cfg.sliding_window if local else None
    o = attn_lib.attention(q, k, v, causal=causal, window=window,
                           q_offset=off, chunk=cfg.attention_chunk)
    x = x + o.reshape(b, s, -1) @ p["attn"]["wo"]
    x = constrain_batch(x, None, None)
    if enc_out is not None and "xattn" in p:
        q2, k2, v2 = _qkv(cfg, p["xattn"], rms_norm(x, p["lnx"]),
                          x_kv=enc_out)
        o2 = attn_lib.attention(q2, k2, v2, causal=False, chunk=0)
        x = x + o2.reshape(b, s, -1) @ p["xattn"]["wo"]
    x = x + mlp(rms_norm(x, p["ln2"]), p["mlp"])
    return constrain_batch(x, None, None), jnp.float32(0.0)


def cache_init_attn(cfg: ModelConfig, batch: int, max_len: int,
                    cross_len: int = 0):
    dt = dtype_of(cfg.compute_dtype)
    kv, hd = cfg.n_kv_heads, cfg.hd
    c = dict(k=jnp.zeros((batch, max_len, kv, hd), dt),
             v=jnp.zeros((batch, max_len, kv, hd), dt))
    if cross_len:
        c["xk"] = jnp.zeros((batch, cross_len, kv, hd), dt)
        c["xv"] = jnp.zeros((batch, cross_len, kv, hd), dt)
    return c


def decode_attn(cfg: ModelConfig, p, cache, x_t, pos, local: bool = False):
    """x_t: [B,1,d]; pos: scalar int32 cache length before this token."""
    b = x_t.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _qkv(cfg, p["attn"], rms_norm(x_t, p["ln1"]),
                   positions=positions)
    kc, vc = attn_lib.update_cache(cache["k"], cache["v"], k, v, pos)
    cache = dict(cache, k=kc, v=vc)
    window = cfg.sliding_window if local else None
    o = attn_lib.decode_attention(q, kc, vc, pos + 1, window=window)
    x_t = x_t + o.reshape(b, 1, -1) @ p["attn"]["wo"]
    if "xattn" in p and "xk" in cache:
        q2 = (rms_norm(x_t, p["lnx"]) @ p["xattn"]["wq"]).reshape(
            b, 1, cfg.n_heads, cfg.hd)
        o2 = attn_lib.decode_attention(q2, cache["xk"], cache["xv"],
                                       jnp.int32(cache["xk"].shape[1]))
        x_t = x_t + o2.reshape(b, 1, -1) @ p["xattn"]["wo"]
    x_t = x_t + mlp(rms_norm(x_t, p["ln2"]), p["mlp"])
    return x_t, cache


# =========================================================== moe block


def build_moe(cfg: ModelConfig, key):
    ks = _split(key, 6)
    ap, asp = _attn_params(cfg, ks[0])
    dt = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    shapes = moe_params_shape(d, cfg.n_experts, cfg.moe_d_ff)
    mp = {}
    for i, (name, shp) in enumerate(shapes.items()):
        scale = 1.0 / np.sqrt(shp[-2] if len(shp) > 2 else shp[0])
        mp[name] = (jax.random.normal(ks[1 + i % 4], shp, jnp.float32) *
                    scale).astype(dt)
    msp = dict(wg=P(None, _mdl(cfg.n_experts)),
               w1=P(_mdl(cfg.n_experts), None, "data"),
               w3=P(_mdl(cfg.n_experts), None, "data"),
               w2=P(_mdl(cfg.n_experts), "data", None))
    p = dict(ln1=jnp.ones((d,), dt), attn=ap,
             ln2=jnp.ones((d,), dt), moe=mp)
    s = dict(ln1=P(None), attn=asp, ln2=P(None), moe=msp)
    if cfg.moe_dense_residual:
        dp, dsp = _mlp_params(cfg, ks[5], cfg.d_ff)
        p["dense"] = dp
        s["dense"] = dsp
    return p, s


def train_moe(cfg: ModelConfig, p, x, off: int = 0, enc_out=None):
    b, s, d = x.shape
    positions = off + jnp.arange(s)[None, :]
    q, k, v = _qkv(cfg, p["attn"], rms_norm(x, p["ln1"]),
                   positions=positions)
    o = attn_lib.attention(q, k, v, causal=True, chunk=cfg.attention_chunk)
    x = x + o.reshape(b, s, -1) @ p["attn"]["wo"]
    h = rms_norm(x, p["ln2"])
    if cfg.moe_grouped:
        y, aux = moe_ffn_grouped(h, p["moe"], cfg.top_k,
                                 cfg.capacity_factor, cfg.moe_n_groups)
    else:
        y, aux = moe_ffn(h, p["moe"], cfg.top_k, cfg.capacity_factor)
    if "dense" in p:
        y = y + mlp(h, p["dense"])          # Arctic dense residual branch
    x = x + y
    return constrain_batch(x, None, None), aux


def decode_moe(cfg: ModelConfig, p, cache, x_t, pos):
    b = x_t.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _qkv(cfg, p["attn"], rms_norm(x_t, p["ln1"]),
                   positions=positions)
    kc, vc = attn_lib.update_cache(cache["k"], cache["v"], k, v, pos)
    cache = dict(cache, k=kc, v=vc)
    o = attn_lib.decode_attention(q, kc, vc, pos + 1)
    x_t = x_t + o.reshape(b, 1, -1) @ p["attn"]["wo"]
    h = rms_norm(x_t, p["ln2"])
    y, _ = moe_ffn(h, p["moe"], cfg.top_k, cfg.capacity_factor)
    if "dense" in p:
        y = y + mlp(h, p["dense"])
    return x_t + y, cache


# =========================================================== mamba2 block


def _mamba_dims(cfg: ModelConfig):
    d_in = 2 * cfg.d_model
    headdim = 64
    nh = d_in // headdim
    n = cfg.ssm_state
    conv_dim = d_in + 2 * n
    return d_in, headdim, nh, n, conv_dim


def build_mamba2(cfg: ModelConfig, key):
    d = cfg.d_model
    d_in, hdim, nh, n, conv_dim = _mamba_dims(cfg)
    dt = dtype_of(cfg.param_dtype)
    ks = _split(key, 3)
    proj_out = 2 * d_in + 2 * n + nh
    p = dict(
        ln=jnp.ones((d,), dt),
        in_proj=_init_dense(ks[0], d, proj_out, dt),
        conv_w=(jax.random.normal(ks[1], (4, conv_dim), jnp.float32)
                * 0.2).astype(dt),
        a_log=jnp.zeros((nh,), jnp.float32),
        d_skip=jnp.ones((nh,), jnp.float32),
        dt_bias=jnp.zeros((nh,), jnp.float32),
        out_proj=_init_dense(ks[2], d_in, d, dt),
    )
    s = dict(ln=P(None), in_proj=P(None, _mdl(proj_out)),
             conv_w=P(None, None), a_log=P(None), d_skip=P(None),
             dt_bias=P(None), out_proj=P(_mdl(d_in), None))
    return p, s


def _causal_conv(x, w, state=None):
    """Depthwise causal conv, width 4.  x: [B,S,C], w: [4,C].
    state: [B,3,C] previous tokens (decode) or None (zero pad)."""
    if state is None:
        pad = jnp.zeros((x.shape[0], 3, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :]
              for i in range(4))
    new_state = xp[:, -3:]
    return out, new_state


def _mamba_project(cfg, p, x):
    d_in, hdim, nh, n, conv_dim = _mamba_dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + conv_dim]
    dt_raw = zxbcdt[..., d_in + conv_dim:]
    return z, xbc, dt_raw


def train_mamba2(cfg: ModelConfig, p, x, off: int = 0, enc_out=None):
    b, s, d = x.shape
    d_in, hdim, nh, n, conv_dim = _mamba_dims(cfg)
    h = rms_norm(x, p["ln"])
    z, xbc, dt_raw = _mamba_project(cfg, p, h)
    xbc, _ = _causal_conv(xbc, p["conv_w"])
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_in].reshape(b, s, nh, hdim)
    bmat = xbc[..., d_in:d_in + n]
    cmat = xbc[..., d_in + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = (-jnp.exp(p["a_log"]))[None, None, :] * dt          # [B,S,H]
    y, _ = ssm_lib.ssd_chunked(xs * dt[..., None].astype(xs.dtype),
                               a, bmat, cmat, cfg.ssm_chunk)
    y = y.astype(xs.dtype) + xs * p["d_skip"][None, None, :,
                                              None].astype(xs.dtype)
    y = y.reshape(b, s, d_in) * jax.nn.silu(z)
    x = x + (y @ p["out_proj"]).astype(x.dtype)
    return constrain_batch(x, None, None), jnp.float32(0.0)


def cache_init_mamba2(cfg: ModelConfig, batch: int, max_len: int):
    d_in, hdim, nh, n, conv_dim = _mamba_dims(cfg)
    dt = dtype_of(cfg.compute_dtype)
    return dict(conv=jnp.zeros((batch, 3, conv_dim), dt),
                ssm=jnp.zeros((batch, nh, hdim, n), dt))


def decode_mamba2(cfg: ModelConfig, p, cache, x_t, pos):
    b = x_t.shape[0]
    d_in, hdim, nh, n, conv_dim = _mamba_dims(cfg)
    h = rms_norm(x_t, p["ln"])
    z, xbc, dt_raw = _mamba_project(cfg, p, h)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], cache["conv"])
    xbc = jax.nn.silu(xbc)
    xs = xbc[:, 0, :d_in].reshape(b, nh, hdim)
    bmat = xbc[:, 0, d_in:d_in + n]
    cmat = xbc[:, 0, d_in + n:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = (-jnp.exp(p["a_log"]))[None, :] * dt                # [B,H]
    y, ssm = ssm_lib.ssd_decode_step(
        cache["ssm"].astype(jnp.float32),
        (xs * dt[..., None].astype(xs.dtype)).astype(jnp.float32),
        a, bmat.astype(jnp.float32), cmat.astype(jnp.float32))
    y = y.astype(xs.dtype) + xs * p["d_skip"][None, :, None].astype(xs.dtype)
    y = y.reshape(b, 1, d_in) * jax.nn.silu(z)
    x_t = x_t + y @ p["out_proj"]
    return x_t, dict(conv=conv_state.astype(cache["conv"].dtype),
                     ssm=ssm.astype(cache["ssm"].dtype))


# =========================================================== mlstm block


def _mlstm_dims(cfg: ModelConfig):
    dp = int(cfg.d_model * cfg.mlstm_proj_factor)
    h = cfg.n_heads
    hd = dp // h
    return dp, h, hd


def build_mlstm(cfg: ModelConfig, key):
    d = cfg.d_model
    dp, h, hd = _mlstm_dims(cfg)
    dt = dtype_of(cfg.param_dtype)
    ks = _split(key, 6)
    p = dict(
        ln=jnp.ones((d,), dt),
        up=_init_dense(ks[0], d, 2 * dp, dt),
        wq=_init_dense(ks[1], dp, dp, dt),
        wk=_init_dense(ks[2], dp, dp, dt),
        wv=_init_dense(ks[3], dp, dp, dt),
        wif=_init_dense(ks[4], dp, 2 * h, dt),
        down=_init_dense(ks[5], dp, d, dt),
    )
    s = dict(ln=P(None), up=P(None, _mdl(2 * dp)), wq=P(None, _mdl(dp)),
             wk=P(None, _mdl(dp)), wv=P(None, _mdl(dp)),
             wif=P(None, None), down=P(_mdl(dp), None))
    return p, s


def train_mlstm(cfg: ModelConfig, p, x, off: int = 0, enc_out=None):
    b, s, d = x.shape
    dp, h, hd = _mlstm_dims(cfg)
    hx = rms_norm(x, p["ln"])
    up = hx @ p["up"]
    xm, z = up[..., :dp], up[..., dp:]
    q = (xm @ p["wq"]).reshape(b, s, h, hd)
    k = (xm @ p["wk"]).reshape(b, s, h, hd)
    v = (xm @ p["wv"]).reshape(b, s, h, hd)
    gates = xm @ p["wif"]
    ig, fg = gates[..., :h], gates[..., h:]
    y, _ = ssm_lib.mlstm_chunked(q, k, v, ig, fg, cfg.ssm_chunk)
    y = y.astype(x.dtype).reshape(b, s, dp) * jax.nn.silu(z)
    x = x + y @ p["down"]
    return constrain_batch(x, None, None), jnp.float32(0.0)


def cache_init_mlstm(cfg: ModelConfig, batch: int, max_len: int):
    dp, h, hd = _mlstm_dims(cfg)
    dt = dtype_of(cfg.compute_dtype)
    c, n = ssm_lib.mlstm_init_state(batch, h, hd, dt)
    return dict(c=c, n=n)


def decode_mlstm(cfg: ModelConfig, p, cache, x_t, pos):
    b = x_t.shape[0]
    dp, h, hd = _mlstm_dims(cfg)
    hx = rms_norm(x_t, p["ln"])
    up = (hx @ p["up"])[:, 0]
    xm, z = up[..., :dp], up[..., dp:]
    q = (xm @ p["wq"]).reshape(b, h, hd)
    k = (xm @ p["wk"]).reshape(b, h, hd)
    v = (xm @ p["wv"]).reshape(b, h, hd)
    gates = xm @ p["wif"]
    ig, fg = gates[..., :h], gates[..., h:]
    y, (c2, n2) = ssm_lib.mlstm_decode_step((cache["c"], cache["n"]),
                                            q, k, v, ig, fg)
    y = y.astype(x_t.dtype).reshape(b, 1, dp) * jax.nn.silu(z[:, None])
    x_t = x_t + y @ p["down"]
    return x_t, dict(c=c2, n=n2)


# =========================================================== slstm block


def build_slstm(cfg: ModelConfig, key):
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    dt = dtype_of(cfg.param_dtype)
    ks = _split(key, 3)
    p = dict(
        ln=jnp.ones((d,), dt),
        wx=_init_dense(ks[0], d, 4 * d, dt),
        r=(jax.random.normal(ks[1], (4, h, hd, hd), jnp.float32) *
           (0.3 / np.sqrt(hd))).astype(dt),
        out=_init_dense(ks[2], d, d, dt),
    )
    # r sharded on the hd OUTPUT axis: keeps the per-token recurrent
    # einsum's weight-gradient reduction off the sequential scan's
    # critical path (§Perf, xlstm train_4k v2)
    s = dict(ln=P(None), wx=P(None, _mdl(4 * d)),
             r=P(None, None, None, _mdl(hd)),
             out=P(None, _mdl(d)))
    return p, s


def train_slstm(cfg: ModelConfig, p, x, off: int = 0, enc_out=None):
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    hx = rms_norm(x, p["ln"])
    parts = (hx @ p["wx"]).reshape(b, s, 4, h, hd)
    ys, _ = ssm_lib.slstm_scan(parts, p["r"])
    y = ys.astype(x.dtype).reshape(b, s, d) @ p["out"]
    return constrain_batch(x + y, None, None), jnp.float32(0.0)


def cache_init_slstm(cfg: ModelConfig, batch: int, max_len: int):
    h = cfg.n_heads
    hd = cfg.d_model // h
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return dict(c=z, n=z + 1e-6, h=z, m=z - 10.0)


def decode_slstm(cfg: ModelConfig, p, cache, x_t, pos):
    b = x_t.shape[0]
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    hx = rms_norm(x_t, p["ln"])
    parts = (hx @ p["wx"]).reshape(b, 1, 4, h, hd)
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    ys, (c, n, hh, m) = ssm_lib.slstm_scan(parts, p["r"], state)
    y = ys.astype(x_t.dtype).reshape(b, 1, d) @ p["out"]
    return x_t + y, dict(c=c, n=n, h=hh, m=m)


# =========================================================== registry

BUILDERS = {
    "attn": lambda cfg, key: build_attn(cfg, key),
    "attn_local": lambda cfg, key: build_attn(cfg, key, local=True),
    "attn_cross": lambda cfg, key: build_attn(cfg, key, cross=True),
    "moe": build_moe,
    "mamba2": build_mamba2,
    "mlstm": build_mlstm,
    "slstm": build_slstm,
}

TRAIN_FNS = {
    "attn": lambda cfg, p, x, off, enc: train_attn(cfg, p, x, off, enc),
    "attn_local": lambda cfg, p, x, off, enc: train_attn(
        cfg, p, x, off, enc, local=True),
    "attn_cross": lambda cfg, p, x, off, enc: train_attn(cfg, p, x, off, enc),
    "moe": train_moe,
    "mamba2": train_mamba2,
    "mlstm": train_mlstm,
    "slstm": train_slstm,
}

DECODE_FNS = {
    "attn": lambda cfg, p, c, x, pos: decode_attn(cfg, p, c, x, pos),
    "attn_local": lambda cfg, p, c, x, pos: decode_attn(
        cfg, p, c, x, pos, local=True),
    "attn_cross": lambda cfg, p, c, x, pos: decode_attn(cfg, p, c, x, pos),
    "moe": decode_moe,
    "mamba2": decode_mamba2,
    "mlstm": decode_mlstm,
    "slstm": decode_slstm,
}

CACHE_FNS = {
    "attn": cache_init_attn,
    "attn_local": cache_init_attn,
    "attn_cross": cache_init_attn,
    "moe": lambda cfg, b, m: cache_init_attn(cfg, b, m),
    "mamba2": cache_init_mamba2,
    "mlstm": cache_init_mlstm,
    "slstm": cache_init_slstm,
}


def cache_specs(cfg: ModelConfig, kind: str, batch_shard=None,
                seq_shard: Tuple[str, ...] = ()) -> Dict[str, P]:
    """PartitionSpecs for one layer's decode cache.  KV caches shard the
    SEQUENCE axis over ``seq_shard`` (long-context decode) and batch over
    ``batch_shard``; SSM states shard batch and heads."""
    def one(axes):
        if not axes:
            return None
        return axes if len(axes) != 1 else axes[0]

    bs = one(tuple(batch_shard) if batch_shard else ())
    ss = one(tuple(seq_shard))
    if kind in ("attn", "attn_local", "attn_cross", "moe"):
        spec = P(bs, ss, None, None)
        return dict(k=spec, v=spec)
    if kind == "mamba2":
        d_in, hdim, nh, n, conv_dim = _mamba_dims(cfg)
        head_ax = "model" if nh % TP == 0 else None
        return dict(conv=P(bs, None, None),
                    ssm=P(bs, head_ax, None, None))
    if kind == "mlstm":
        return dict(c=P(bs, None, None, None), n=P(bs, None, None, None))
    if kind == "slstm":
        z = P(bs, None, None)
        return dict(c=z, n=z, h=z, m=z)
    raise KeyError(kind)
