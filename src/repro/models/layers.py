"""Shared neural layers (pure JAX, no framework), with sharding metadata.

Every parameter-creating helper returns ``(params, specs)`` where ``specs``
mirrors the params pytree with ``jax.sharding.PartitionSpec`` leaves.  Axis
name conventions:

    "data"  — batch / FSDP axis       (16 per pod)
    "model" — tensor-parallel axis    (16)
    "pod"   — pod axis (multi-pod only; batch is sharded over
              ("pod", "data") jointly)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------- init


def _init_dense(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) *
            scale).astype(dtype)


def dense_param(key, d_in: int, d_out: int, dtype,
                spec: P) -> Tuple[jnp.ndarray, P]:
    return _init_dense(key, d_in, d_out, dtype), spec


def norm_param(d: int, dtype) -> Tuple[jnp.ndarray, P]:
    return jnp.ones((d,), dtype), P(None)


# ---------------------------------------------------------------- ops


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6
             ) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * w.astype(dt)


def swiglu(x: jnp.ndarray, w1: jnp.ndarray, w3: jnp.ndarray,
           w2: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU MLP: (silu(x@w1) * (x@w3)) @ w2."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def mlp(x: jnp.ndarray, p: dict) -> jnp.ndarray:
    """Dispatch on params: SwiGLU if w3 present, else GELU 2-matrix."""
    if "w3" in p:
        return swiglu(x, p["w1"], p["w3"], p["w2"])
    return jax.nn.gelu(x @ p["w1"]) @ p["w2"]


# ---------------------------------------------------------------- RoPE


def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]                     # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_m_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                 sections=(2, 1, 1)) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: head_dim is split into (temporal, h, w)
    sections, each rotated by its own position stream.  For the text-only
    backbone stub all three streams share the token index (the paper's
    degenerate case), but the decomposition — and its cost — is real.

    x: [..., S, H, hd]; positions: [..., S, 3] or [..., S] (broadcast).
    """
    if positions.ndim == x.ndim - 2:                     # [..., S] -> 3 copies
        positions = jnp.stack([positions] * 3, axis=-1)
    hd = x.shape[-1]
    total = sum(sections)
    splits = [s * hd // (2 * total) for s in sections]   # per-section hd/2
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    f_parts = jnp.split(freqs, np.cumsum(splits)[:-1])
    angs = []
    for i, fp in enumerate(f_parts):
        angs.append(positions[..., i:i + 1].astype(jnp.float32) * fp)
    ang = jnp.concatenate(angs, axis=-1)                 # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- loss


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 softcap: float = 0.0) -> jnp.ndarray:
    """Mean cross entropy; logits [.., V] bf16-safe (reductions in f32)."""
    lg = logits.astype(jnp.float32)
    if softcap > 0.0:
        lg = jnp.tanh(lg / softcap) * softcap
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
