"""Sequence-mixing recurrences: Mamba-2 SSD (zamba2), xLSTM mLSTM/sLSTM.

The chunked SSD kernel (Dao & Gu, 2024, "minimal SSD") is the shared
engine: intra-chunk work is dense matmuls (MXU-friendly), inter-chunk state
is carried by a short ``lax.scan`` over S/chunk steps.  The mLSTM's
chunkwise-parallel form is SSD with (B=k, C=q, x=i*v, A=log f), so it
reuses the same kernel; its normalizer runs the same recurrence with P=1.

The sLSTM is sequential by construction (state mixing defeats
parallelization — the xLSTM paper says as much), so it is a per-token
``lax.scan``; its per-step cost is a small block-diagonal matmul.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a: [..., q] -> [..., q, q] lower-triangular pairwise sums:
    out[..., i, j] = sum(a[..., j+1 : i+1]) for i >= j, -inf above."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]           # sum(j+1..i)
    mask = jnp.tril(jnp.ones((q, q), dtype=bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                c: jnp.ndarray, chunk: int,
                h0: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked state-space dual form.

    x: [B,S,H,P]   (already dt-scaled inputs)
    a: [B,S,H]     log-decay per token (<= 0)
    b: [B,S,N]     input projection  (shared across heads, 1 group)
    c: [B,S,N]     output projection
    returns y: [B,S,H,P], final state [B,H,P,N]
    """
    B, S, H, Pd = x.shape
    N = b.shape[-1]
    assert S % chunk == 0, f"S={S} not divisible by chunk={chunk}"
    nc = S // chunk
    xc = x.reshape(B, nc, chunk, H, Pd)
    ac = a.reshape(B, nc, chunk, H)
    bc = b.reshape(B, nc, chunk, N)
    cc = c.reshape(B, nc, chunk, N)

    acs = jnp.cumsum(ac, axis=2)                          # [B,nc,q,H]
    # intra-chunk (diagonal) term
    L = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))        # [B,nc,H,q,q]
    y_diag = jnp.einsum("bcln,bcsn,bchls,bcshp->bclhp",
                        cc, bc, L, xc)
    # states emitted by each chunk
    decay_states = jnp.exp(acs[:, :, -1:, :] - acs)       # [B,nc,q,H]
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn",
                        bc, decay_states, xc)             # [B,nc,H,P,N]
    # inter-chunk recurrence
    chunk_decay = jnp.exp(acs[:, :, -1, :])               # [B,nc,H]
    if h0 is None:
        h0 = jnp.zeros((B, H, Pd, N), x.dtype)

    def step(h, inp):
        dec, st = inp                                     # [B,H], [B,H,P,N]
        h_out = h                                         # state BEFORE chunk
        h = h * dec[:, :, None, None] + st
        return h, h_out

    hT, h_prev = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (chunk_decay.transpose(1, 0, 2).astype(jnp.float32),
         states.transpose(1, 0, 2, 3, 4).astype(jnp.float32)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)              # [B,nc,H,P,N]
    # off-diagonal (carried-state) term
    state_decay = jnp.exp(acs)                            # [B,nc,q,H]
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp",
                       cc, h_prev.astype(x.dtype), state_decay)
    y = (y_diag + y_off).reshape(B, S, H, Pd)
    return y, hT.astype(x.dtype)


def ssd_decode_step(h: jnp.ndarray, x_t: jnp.ndarray, a_t: jnp.ndarray,
                    b_t: jnp.ndarray, c_t: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-token recurrence.  h: [B,H,P,N], x_t: [B,H,P], a_t: [B,H],
    b_t/c_t: [B,N] -> (y_t [B,H,P], h')."""
    dec = jnp.exp(a_t)[:, :, None, None]
    h = h * dec + jnp.einsum("bhp,bn->bhpn", x_t, b_t)
    y = jnp.einsum("bhpn,bn->bhp", h, c_t)
    return y, h


# ---------------------------------------------------------------- mLSTM


def mlstm_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  i_gate: jnp.ndarray, f_gate: jnp.ndarray, chunk: int,
                  state: Optional[Tuple] = None
                  ) -> Tuple[jnp.ndarray, Tuple]:
    """Matrix-LSTM in chunkwise-parallel form (xLSTM).

    q/k/v: [B,S,H,hd]; i_gate/f_gate: [B,S,H] (pre-activations).
    C_t = f C_{t-1} + i v k^T ; n_t = f n_{t-1} + i k ;
    y = (C q) / max(|n.q|, 1).
    Maps onto SSD with a = log sigmoid(f), x = i*v, b = k, c = q;
    the normalizer runs the same recurrence with x = i*1.
    """
    B, S, H, hd = q.shape
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))  # [B,S,H]
    i_act = jnp.exp(jnp.minimum(i_gate.astype(jnp.float32), 10.0))
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    # fold heads: b/c must be [B,S,N] per head group -> run per-head via
    # merging H into the batch axis (SSD supports 1 group; heads here have
    # distinct k/q so each head is its own group).
    def fold(t):         # [B,S,H,D] -> [B*H,S,1,D] with H folded in batch
        return t.transpose(0, 2, 1, 3).reshape(B * H, S, 1, t.shape[-1])

    xq = fold(v * i_act[..., None].astype(v.dtype))
    a = logf.transpose(0, 2, 1).reshape(B * H, S, 1)
    bmat = fold(k * scale).reshape(B * H, S, hd)
    cmat = fold(q).reshape(B * H, S, hd)
    h0 = None if state is None else state[0]
    y, hT = ssd_chunked(xq, a, bmat, cmat, chunk, h0)
    # normalizer n_t . q_t via the same recurrence with x = i (P=1)
    ones = jnp.ones((B * H, S, 1, 1), v.dtype) * \
        i_act.transpose(0, 2, 1).reshape(B * H, S, 1, 1).astype(v.dtype)
    n0 = None if state is None else state[1]
    nrm, nT = ssd_chunked(ones, a, bmat, cmat, chunk, n0)
    denom = jnp.maximum(jnp.abs(nrm[..., 0]), 1.0)        # [B*H,S,1]
    y = y[:, :, 0] / denom                                # [B*H,S,hd]
    y = y.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    return y, (hT, nT)


def mlstm_init_state(batch: int, n_heads: int, hd: int, dtype):
    return (jnp.zeros((batch * n_heads, 1, hd, hd), dtype),
            jnp.zeros((batch * n_heads, 1, 1, hd), dtype))


def mlstm_decode_step(state, q_t, k_t, v_t, i_t, f_t):
    """One-token mLSTM.  q/k/v: [B,H,hd], gates [B,H].
    state = (C [B*H,1,hd,hd], n [B*H,1,1,hd]) as from mlstm_init_state."""
    B, H, hd = q_t.shape
    C, n = state
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    logf = jax.nn.log_sigmoid(f_t.astype(jnp.float32)).reshape(B * H, 1)
    i_act = jnp.exp(jnp.minimum(i_t.astype(jnp.float32),
                                10.0)).reshape(B * H)
    kf = (k_t * scale).reshape(B * H, hd).astype(C.dtype)
    qf = q_t.reshape(B * H, hd).astype(C.dtype)
    vf = (v_t.reshape(B * H, hd) * i_act[:, None]).astype(C.dtype)
    # SSD layout: h [B',1,P,N] with the fused B*H batch and one "head"
    y, C2 = ssd_decode_step(C, vf[:, None, :], logf, kf, qf)  # [B',1,hd]
    ones = i_act[:, None, None].astype(C.dtype)               # x=i, P=1
    nrm, n2 = ssd_decode_step(n, ones, logf, kf, qf)          # [B',1,1]
    denom = jnp.maximum(jnp.abs(nrm), 1.0)
    y = (y / denom).reshape(B, H, hd)
    return y, (C2, n2)


# ---------------------------------------------------------------- sLSTM


def slstm_scan(x_parts: jnp.ndarray, r_weights: jnp.ndarray,
               state: Optional[Tuple] = None
               ) -> Tuple[jnp.ndarray, Tuple]:
    """Scalar-LSTM with exponential gating + per-head state mixing.

    x_parts: [B,S,4,H,hd] — precomputed W{z,i,f,o} @ x per token.
    r_weights: [4,H,hd,hd] — recurrent block-diagonal matrices.
    Sequential scan over S (state mixing is inherently serial).
    Returns h_seq [B,S,H,hd] and final state (c,n,h,m).
    """
    B, S, _, H, hd = x_parts.shape
    f32 = jnp.float32
    if state is None:
        z0 = jnp.zeros((B, H, hd), f32)
        state = (z0, z0 + 1e-6, z0, z0 - 10.0)            # c, n, h, m

    def step(carry, xt):                                  # xt: [B,4,H,hd]
        c, n, h, m = carry
        rz = jnp.einsum("bhd,hde->bhe", h, r_weights[0].astype(f32))
        ri = jnp.einsum("bhd,hde->bhe", h, r_weights[1].astype(f32))
        rf = jnp.einsum("bhd,hde->bhe", h, r_weights[2].astype(f32))
        ro = jnp.einsum("bhd,hde->bhe", h, r_weights[3].astype(f32))
        zt = jnp.tanh(xt[:, 0].astype(f32) + rz)
        it = xt[:, 1].astype(f32) + ri
        ft = xt[:, 2].astype(f32) + rf
        ot = jax.nn.sigmoid(xt[:, 3].astype(f32) + ro)
        m2 = jnp.maximum(ft + m, it)                      # stabilizer
        ip = jnp.exp(it - m2)
        fp = jnp.exp(ft + m - m2)
        c2 = fp * c + ip * zt
        n2 = fp * n + ip
        h2 = ot * c2 / jnp.maximum(n2, 1.0)
        return (c2, n2, h2, m2), h2

    final, hs = jax.lax.scan(step, state,
                             x_parts.transpose(1, 0, 2, 3, 4))
    return hs.transpose(1, 0, 2, 3), final
