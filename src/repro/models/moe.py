"""Top-k routed mixture-of-experts FFN (GShard-style capacity dispatch).

Dispatch is the standard scatter/gather formulation: top-k routing, position
within expert via a cumulative-sum over the one-hot assignment matrix,
capacity-bounded buffers [E, C, d], SwiGLU expert compute as batched
einsums, weighted combine.  Tokens overflowing an expert's capacity are
dropped (pass through the residual), capacity_factor defaults to 1.25.

Sharding intent (constrained in model.py): tokens sharded over the batch
axes, experts over "model", expert hidden dim over "data" — so expert
compute is fully distributed and dispatch lowers to all-to-alls.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp



def moe_params_shape(d_model: int, n_experts: int, d_ff: int):
    return dict(
        wg=(d_model, n_experts),
        w1=(n_experts, d_model, d_ff),
        w3=(n_experts, d_model, d_ff),
        w2=(n_experts, d_ff, d_model),
    )


def moe_ffn_grouped(x: jnp.ndarray, p: Dict[str, jnp.ndarray], top_k: int,
                    capacity_factor: float = 1.25, n_groups: int = 256
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Grouped (GShard-style) dispatch — the §Perf hillclimb variant.

    Tokens are split into ``n_groups`` groups aligned with the data
    shards; each group owns a PRIVATE capacity slice of every expert, so
    position computation and the dispatch scatter stay group-local (no
    cross-shard scatter → XLA lowers the layout change to the canonical
    MoE all-to-all instead of materializing the full [E,C,d] buffer on
    every device — see EXPERIMENTS.md §Perf, kimi train_4k).

    x: [B,S,d] -> (y [B,S,d], aux_loss).
    """
    b, s, d = x.shape
    e = p["wg"].shape[1]
    t = b * s
    g = min(n_groups, t)
    while t % g != 0:
        g //= 2
    tg = t // g
    xf = x.reshape(g, tg, d)

    logits = (xf @ p["wg"]).astype(jnp.float32)            # [G,Tg,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)             # [G,Tg,k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[
        top_i[..., 0].reshape(-1)].add(1.0) / t
    aux = e * jnp.sum(me * ce)

    cap = max(1, int(tg * top_k * capacity_factor / e))    # per group

    flat_e = top_i.reshape(g, tg * top_k)                  # [G, Tg*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)    # [G, Tg*k, E]
    pos = jnp.cumsum(onehot, axis=1) - 1
    flat_pos = jnp.take_along_axis(
        pos, flat_e[..., None], axis=2)[..., 0]            # [G, Tg*k]
    keep = flat_pos < cap
    flat_w = top_p.reshape(g, tg * top_k) * keep
    safe_pos = jnp.where(keep, flat_pos, cap - 1)

    xk = jnp.repeat(xf, top_k, axis=1)                     # [G, Tg*k, d]
    buf = jnp.zeros((g, e, cap, d), x.dtype)
    gidx = jnp.arange(g, dtype=jnp.int32)[:, None] * \
        jnp.ones((1, tg * top_k), jnp.int32)
    buf = buf.at[gidx, flat_e, safe_pos].add(
        jnp.where(keep[..., None], xk, 0).astype(x.dtype))

    # expert compute over the group-private capacity slices
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w1"])) * \
        jnp.einsum("gecd,edf->gecf", buf, p["w3"])
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w2"])     # [G,E,C,d]

    gathered = out_buf[gidx, flat_e, safe_pos]             # [G, Tg*k, d]
    yk = gathered * flat_w[..., None].astype(x.dtype)
    y = yk.reshape(g, tg, top_k, d).sum(axis=2)
    return y.reshape(b, s, d), aux


def moe_ffn(x: jnp.ndarray, p: Dict[str, jnp.ndarray], top_k: int,
            capacity_factor: float = 1.25) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B,S,d] -> (y [B,S,d], aux_loss scalar)."""
    b, s, d = x.shape
    e = p["wg"].shape[1]
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf @ p["wg"]).astype(jnp.float32)            # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)             # [T,k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=0)                                # [E]
    ce = jnp.zeros((e,), jnp.float32).at[top_i[:, 0]].add(1.0) / t
    aux = e * jnp.sum(me * ce)

    cap = max(1, int(t * top_k * capacity_factor / e))

    # position of each (token, slot) within its expert
    flat_e = top_i.reshape(-1)                             # [T*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)    # [T*k, E]
    pos = jnp.cumsum(onehot, axis=0) - 1                   # [T*k, E]
    flat_pos = jnp.take_along_axis(pos, flat_e[:, None],
                                   axis=1)[:, 0]           # [T*k]
    keep = flat_pos < cap
    flat_w = top_p.reshape(-1) * keep                      # dropped -> 0

    # dispatch: buffers [E, C, d]
    xk = jnp.repeat(xf, top_k, axis=0)                     # [T*k, d]
    safe_pos = jnp.where(keep, flat_pos, cap - 1)
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[flat_e, safe_pos].add(
        jnp.where(keep[:, None], xk, 0).astype(x.dtype))

    # expert compute (batched SwiGLU)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w1"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w2"])       # [E,C,d]

    # combine
    gathered = out_buf[flat_e, safe_pos]                   # [T*k, d]
    yk = gathered * flat_w[:, None].astype(x.dtype)
    y = yk.reshape(t, top_k, d).sum(axis=1)
    return y.reshape(b, s, d), aux
