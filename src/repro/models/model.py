"""Model assembly: embeddings + scanned super-blocks + LM head.

Weights of each pattern entry are stacked [n_super, repeat, ...] and the
super-block body is compiled ONCE and driven by ``jax.lax.scan`` — compile
time is independent of depth.  Zamba2-style *shared* blocks keep a single
(unstacked) copy of their weights, referenced from the scan body closure,
while their KV caches remain per-layer.

Public surface:
    m = Model(cfg)
    params = m.init(key)
    specs  = m.param_specs()
    loss, aux = m.loss_fn(params, batch)
    cache  = m.init_cache(batch_size, max_len[, enc_embeds, params])
    logits, cache = m.decode_step(params, cache, tokens, pos)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import blocks as blk
from .config import BlockSpec, ModelConfig
from .layers import dtype_of, rms_norm, softmax_xent, _init_dense
from .sharding import constrain_batch

SHARED_KINDS = {"shared_attn"}      # zamba2: one weight copy, many uses


def _entry_kind(b: BlockSpec) -> str:
    return "attn" if b.kind == "shared_attn" else b.kind


def _stack_specs(tree, n_lead: int):
    return jax.tree.map(
        lambda s: P(*([None] * n_lead + list(s))), tree,
        is_leaf=lambda x: isinstance(x, P))


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------ init
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dt = dtype_of(cfg.param_dtype)
        keys = jax.random.split(key, 8 + len(cfg.pattern))
        params: Dict[str, Any] = {}
        params["embed"] = (jax.random.normal(
            keys[0], (cfg.vocab_size, cfg.d_model), jnp.float32)
            * 0.02).astype(dt)
        if not cfg.tie_embeddings:
            params["unembed"] = _init_dense(keys[1], cfg.d_model,
                                            cfg.vocab_size, dt)
        params["final_ln"] = jnp.ones((cfg.d_model,), dt)

        for i, b in enumerate(cfg.pattern):
            kind = _entry_kind(b)
            builder = blk.BUILDERS[kind]
            if b.kind in SHARED_KINDS:
                p, _ = builder(cfg, keys[3 + i])
                params[f"g{i}"] = p
            else:
                kk = jax.random.split(keys[3 + i],
                                      cfg.n_super * b.repeat)
                kk = kk.reshape(cfg.n_super, b.repeat, -1)
                p = jax.vmap(jax.vmap(lambda k: builder(cfg, k)[0]))(kk)
                params[f"g{i}"] = p

        if cfg.n_enc_layers:
            kk = jax.random.split(keys[2], cfg.n_enc_layers)
            params["enc"] = jax.vmap(
                lambda k: blk.build_attn(cfg, k)[0])(kk)
            params["enc_ln"] = jnp.ones((cfg.d_model,), dt)
        return params

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        specs: Dict[str, Any] = {}
        if cfg.embed_shard == "vocab":
            specs["embed"] = P(blk._mdl(cfg.vocab_size), None)
        else:
            specs["embed"] = P(None, blk._mdl(cfg.d_model))
        if not cfg.tie_embeddings:
            specs["unembed"] = P(None, blk._mdl(cfg.vocab_size))
        specs["final_ln"] = P(None)

        def abstract_specs(builder):
            # run the builder abstractly (no weight allocation); the spec
            # tree is captured from the traced call
            cap = {}

            def f(k):
                p, s = builder(cfg, k)
                cap["s"] = s
                return p

            jax.eval_shape(f, jax.random.PRNGKey(0))
            return cap["s"]

        for i, b in enumerate(cfg.pattern):
            kind = _entry_kind(b)
            s = abstract_specs(blk.BUILDERS[kind])
            if b.kind in SHARED_KINDS:
                specs[f"g{i}"] = s
            else:
                specs[f"g{i}"] = _stack_specs(s, 2)
        if cfg.n_enc_layers:
            s = abstract_specs(lambda c, k: blk.build_attn(c, k))
            specs["enc"] = _stack_specs(s, 1)
            specs["enc_ln"] = P(None)
        return specs

    # ------------------------------------------------------------ fwd
    def _encoder(self, params, enc_embeds):
        cfg = self.cfg
        x = constrain_batch(enc_embeds.astype(dtype_of(cfg.compute_dtype)),
                            None, None)

        def body(x, layer_p):
            x, _ = blk.train_attn(cfg, layer_p, x, causal=False)
            return x, None

        body = _maybe_remat(body, cfg.remat)
        x, _ = jax.lax.scan(body, x, params["enc"])
        return rms_norm(x, params["enc_ln"])

    def _backbone(self, params, x, enc_out=None):
        """Run the scanned super-blocks.  x: [B,S,d]."""
        cfg = self.cfg
        scanned = {f"g{i}": params[f"g{i}"]
                   for i, b in enumerate(cfg.pattern)
                   if b.kind not in SHARED_KINDS}
        shared = {f"g{i}": params[f"g{i}"]
                  for i, b in enumerate(cfg.pattern)
                  if b.kind in SHARED_KINDS}

        def super_body(carry, xs):
            x, aux = carry
            for i, b in enumerate(cfg.pattern):
                kind = _entry_kind(b)
                fn = blk.TRAIN_FNS[kind]
                if b.kind in SHARED_KINDS:
                    for _ in range(b.repeat):
                        x, a = fn(cfg, shared[f"g{i}"], x, 0, enc_out)
                        aux = aux + a
                else:
                    for r in range(b.repeat):
                        p_r = jax.tree.map(lambda t: t[r], xs[f"g{i}"])
                        x, a = fn(cfg, p_r, x, 0, enc_out)
                        aux = aux + a
            return (x, aux), None

        super_body = _maybe_remat(super_body, cfg.remat)
        (x, aux), _ = jax.lax.scan(super_body, (x, jnp.float32(0.0)),
                                   scanned, length=cfg.n_super)
        return x, aux

    def forward(self, params, tokens, frontend=None, enc_embeds=None):
        """tokens: [B,S_text] int32; frontend: [B,nf,d] embeddings
        prepended to the text stream (vlm/audio stubs); enc_embeds:
        [B,S_enc,d] encoder input (enc-dec).  Returns logits [B,S,V]."""
        cfg = self.cfg
        x = params["embed"][tokens].astype(dtype_of(cfg.compute_dtype))
        if frontend is not None:
            x = jnp.concatenate(
                [frontend.astype(x.dtype), x], axis=1)
        x = constrain_batch(x, None, None)
        enc_out = None
        if enc_embeds is not None:
            enc_out = self._encoder(params, enc_embeds)
        x, aux = self._backbone(params, x, enc_out)
        x = rms_norm(x, params["final_ln"])
        if cfg.tie_embeddings:
            logits = x @ params["embed"].T.astype(x.dtype)
        else:
            logits = x @ params["unembed"]
        logits = constrain_batch(logits, None, "model")
        return logits, aux

    def loss_fn(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        logits, aux = self.forward(
            params, batch["tokens"],
            frontend=batch.get("frontend"),
            enc_embeds=batch.get("enc_embeds"))
        if batch.get("frontend") is not None:
            logits = logits[:, batch["frontend"].shape[1]:]
        loss = softmax_xent(logits, batch["labels"], cfg.logit_softcap)
        total = loss + 0.01 * aux
        return total, dict(xent=loss, aux=aux)

    # ------------------------------------------------------------ decode
    def init_cache(self, batch: int, max_len: int,
                   params=None, enc_embeds=None) -> Dict[str, Any]:
        cfg = self.cfg
        cache: Dict[str, Any] = {}
        for i, b in enumerate(cfg.pattern):
            kind = _entry_kind(b)
            one = blk.CACHE_FNS[kind](cfg, batch, max_len)
            cache[f"g{i}"] = jax.tree.map(
                lambda t: jnp.broadcast_to(
                    t[None, None], (cfg.n_super, b.repeat) + t.shape), one)
        if cfg.n_enc_layers and params is not None and enc_embeds is not None:
            enc_out = self._encoder(params, enc_embeds)

            def xkv(layer_p):
                k = (enc_out @ layer_p["attn"]["wk"]).reshape(
                    batch, -1, cfg.n_kv_heads, cfg.hd)
                v = (enc_out @ layer_p["attn"]["wv"]).reshape(
                    batch, -1, cfg.n_kv_heads, cfg.hd)
                return k, v

            # decoder cross-attn K/V per layer (pattern entry 0 is the
            # decoder block for enc-dec configs)
            for i, b in enumerate(cfg.pattern):
                if _entry_kind(b) == "attn_cross":
                    ks, vs = jax.vmap(jax.vmap(
                        lambda p: xkv(p)))(params[f"g{i}"])
                    cache[f"g{i}"]["xk"] = ks
                    cache[f"g{i}"]["xv"] = vs
        return cache

    def cache_specs(self, batch_shard=None,
                    seq_shard: Tuple[str, ...] = ()) -> Dict[str, Any]:
        cfg = self.cfg
        specs: Dict[str, Any] = {}
        for i, b in enumerate(cfg.pattern):
            kind = _entry_kind(b)
            s = blk.cache_specs(cfg, kind, batch_shard, seq_shard)
            specs[f"g{i}"] = jax.tree.map(
                lambda sp: P(*([None, None] + list(sp))), s,
                is_leaf=lambda x: isinstance(x, P))
            if kind == "attn_cross":
                xs = blk.cache_specs(cfg, "attn", batch_shard, seq_shard)
                specs[f"g{i}"]["xk"] = P(None, None, *xs["k"])
                specs[f"g{i}"]["xv"] = P(None, None, *xs["v"])
        return specs

    def decode_step(self, params, cache, tokens, pos):
        """tokens: [B,1] int32; pos: scalar int32 (current cache length).
        Returns (logits [B,1,V], new cache)."""
        cfg = self.cfg
        x = params["embed"][tokens].astype(dtype_of(cfg.compute_dtype))
        scanned_p = {f"g{i}": params[f"g{i}"]
                     for i, b in enumerate(cfg.pattern)
                     if b.kind not in SHARED_KINDS}
        shared = {f"g{i}": params[f"g{i}"]
                  for i, b in enumerate(cfg.pattern)
                  if b.kind in SHARED_KINDS}
        scanned_c = {f"g{i}": cache[f"g{i}"]
                     for i, b in enumerate(cfg.pattern)}

        def super_body(x, xs):
            p_all, c_all = xs
            c_new = {}
            for i, b in enumerate(cfg.pattern):
                kind = _entry_kind(b)
                fn = blk.DECODE_FNS[kind]
                outs = []
                for r in range(b.repeat):
                    c_r = jax.tree.map(lambda t: t[r], c_all[f"g{i}"])
                    if b.kind in SHARED_KINDS:
                        p_r = shared[f"g{i}"]
                    else:
                        p_r = jax.tree.map(lambda t: t[r], p_all[f"g{i}"])
                    x, c_r = fn(cfg, p_r, c_r, x, pos)
                    outs.append(c_r)
                c_new[f"g{i}"] = jax.tree.map(
                    lambda *ts: jnp.stack(ts), *outs)
            return x, c_new

        x, new_scanned_c = jax.lax.scan(super_body, x,
                                        (scanned_p, scanned_c))
        x = rms_norm(x, params["final_ln"])
        if cfg.tie_embeddings:
            logits = x @ params["embed"].T.astype(x.dtype)
        else:
            logits = x @ params["unembed"]
        cache = dict(cache, **new_scanned_c)
        return logits, cache


def _maybe_remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)
