"""Model configuration for all assigned architectures.

A model is a sequence of *block groups*; each group is a homogeneous stack
of blocks scanned with ``jax.lax.scan`` (weights stacked on a leading layer
axis) so XLA compiles ONE block body per group regardless of depth.
Heterogeneous archs (gemma3 5:1 local:global, zamba2 mamba+shared-attn,
xlstm mLSTM/sLSTM alternation) are expressed as a repeating *super-block*
of a few block kinds.

Block kinds:
    "attn"        full-attention + SwiGLU MLP (pre-RMSNorm, residual)
    "attn_local"  sliding-window attention + MLP
    "moe"         attention + mixture-of-experts FFN (optionally + dense
                  residual FFN, Arctic-style)
    "mamba2"      Mamba-2 SSD block
    "mlstm"       xLSTM matrix-LSTM block
    "slstm"       xLSTM scalar-LSTM block
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    kind: str
    repeat: int = 1                 # consecutive layers of this kind


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # super-block pattern, repeated ``n_super`` times
    pattern: Tuple[BlockSpec, ...] = (BlockSpec("attn"),)
    n_super: int = 1
    head_dim: Optional[int] = None          # default d_model // n_heads
    # attention
    rope_theta: float = 10_000.0
    sliding_window: int = 4096              # for "attn_local"
    m_rope: bool = False                    # Qwen2-VL multimodal RoPE
    attention_chunk: int = 2048             # q-chunking for long sequences
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_dense_residual: bool = False        # Arctic: dense FFN in parallel
    capacity_factor: float = 1.25
    moe_grouped: bool = False               # GShard-style grouped dispatch
    moe_n_groups: int = 256                 # groups (= data shards ideally)
    # SSM
    ssm_state: int = 64
    ssm_chunk: int = 256
    mlstm_proj_factor: float = 2.0
    # encoder-decoder
    n_enc_layers: int = 0                   # >0 => enc-dec model
    # multimodal stub frontends (precomputed embeddings via input_specs)
    frontend: Optional[str] = None          # None | "vision" | "audio"
    n_frontend_tokens: int = 0              # prepended embedding positions
    # FFN
    mlp_kind: str = "swiglu"                # swiglu | gelu (2-matrix)
    embed_shard: str = "vocab"              # vocab | dmodel (perf variant)
    # numerics / training
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "full"                     # none | full | dots
    # sub-quadratic? (drives long_500k applicability)
    subquadratic: bool = False
    logit_softcap: float = 0.0
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_layers(self) -> int:
        per = sum(b.repeat for b in self.pattern)
        return per * self.n_super + self.n_enc_layers

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        def attn_params():
            return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + \
                self.n_heads * hd * d
        def mlp_params(ff):
            return (3 if self.mlp_kind == "swiglu" else 2) * d * ff
        for blk in self.pattern:
            n = blk.repeat * self.n_super
            if blk.kind in ("attn", "attn_local"):
                total += n * (attn_params() + mlp_params(self.d_ff))
            elif blk.kind == "moe":
                e = n * (attn_params() + d * self.n_experts +
                         self.n_experts * 3 * d * self.moe_d_ff)
                if self.moe_dense_residual:
                    e += n * mlp_params(self.d_ff)
                total += e
            elif blk.kind == "mamba2":
                din = 2 * d
                total += n * (d * (2 * din + 2 * self.ssm_state *
                                   (din // 64)) + din * d + 3 * din)
            elif blk.kind in ("mlstm", "slstm"):
                dp = int(d * self.mlstm_proj_factor)
                total += n * (d * dp * 2 + 4 * d * dp // 4 * 4)
        if self.n_enc_layers:
            total += self.n_enc_layers * (attn_params() +
                                          mlp_params(self.d_ff))
            # decoder cross-attention
            dec_layers = sum(b.repeat for b in self.pattern) * self.n_super
            total += dec_layers * attn_params()
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - (
            sum(b.repeat for b in self.pattern if b.kind == "moe") *
            self.n_super * self.n_experts * 3 * d * self.moe_d_ff)
        n_moe = sum(b.repeat for b in self.pattern
                    if b.kind == "moe") * self.n_super
        return dense + n_moe * self.top_k * 3 * d * self.moe_d_ff
