"""Deterministic synthetic token pipeline with sharded host feeding.

Production layering without external data deps: an infinite, seekable
stream of language-modeling batches derived from a counter-based PRNG —
``batch_at(step)`` is a pure function, so restarts resume EXACTLY at the
failed step (checkpoint stores only the step counter) and any host can
materialize any shard of any batch (elastic re-sharding is trivial).

A Zipf-ish marginal over the vocabulary plus a deterministic n-gram-like
mixing makes the loss non-trivial (models actually learn on it — see
tests/test_archs_smoke.py::test_loss_decreases_on_fixed_batch).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.2
    frontend: Optional[str] = None       # None | vision | audio
    n_frontend_tokens: int = 0
    d_model: int = 0


class SyntheticLM:
    """Counter-based deterministic batch source."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / np.power(ranks, cfg.zipf_a)
        self._probs = probs / probs.sum()
        # fixed token-mixing matrix for pseudo-ngram structure
        self._mix = rng.integers(1, cfg.vocab_size,
                                 size=4096).astype(np.int64)

    def batch_at(self, step: int,
                 shard: Tuple[int, int] = (0, 1)) -> Dict[str, np.ndarray]:
        """Batch for ``step``; ``shard=(i, n)`` returns the i-th of n
        equal slices along the batch axis (per-host feeding)."""
        cfg = self.cfg
        i, n = shard
        assert cfg.global_batch % n == 0
        b = cfg.global_batch // n
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, i]))
        base = rng.choice(cfg.vocab_size, size=(b, cfg.seq_len + 1),
                          p=self._probs)
        # deterministic structure: x[t+1] correlates with mix[x[t] % 4096]
        structured = self._mix[base[:, :-1] % 4096] % cfg.vocab_size
        use = rng.random((b, cfg.seq_len)) < 0.5
        tokens = np.where(use, structured, base[:, 1:]).astype(np.int32)
        prev = base[:, :-1].astype(np.int32)
        out = {"tokens": prev, "labels": tokens}
        if cfg.frontend == "vision":
            out["frontend"] = rng.standard_normal(
                (b, cfg.n_frontend_tokens, cfg.d_model)
            ).astype(np.float32) * 0.02
            out["tokens"] = out["tokens"][:, :cfg.seq_len -
                                          cfg.n_frontend_tokens]
            out["labels"] = out["labels"][:, :cfg.seq_len -
                                          cfg.n_frontend_tokens]
        if cfg.frontend == "audio":
            out["enc_embeds"] = rng.standard_normal(
                (b, cfg.seq_len, cfg.d_model)).astype(np.float32) * 0.02
        return out

    def iterate(self, start_step: int = 0,
                shard: Tuple[int, int] = (0, 1)) -> Iterator[Dict]:
        step = start_step
        while True:
            yield self.batch_at(step, shard)
            step += 1


def make_data(model_cfg, shape) -> SyntheticLM:
    """Build a pipeline matched to a model config + shape cell."""
    return SyntheticLM(DataConfig(
        vocab_size=model_cfg.vocab_size,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        frontend=model_cfg.frontend,
        n_frontend_tokens=model_cfg.n_frontend_tokens,
        d_model=model_cfg.d_model,
    ))
