"""Blocked causal flash attention (Pallas TPU) with online softmax.

The perf-critical attention layer of the LM stack.  Block-level causal
skipping: KV blocks strictly above the diagonal are never fetched or
computed (the grid dimension is bounded per q-block via the index map +
``pl.when`` predication) — the same tile-granular Skip idea as
``bsr_spmm``, with causality as the (static) sparsity pattern.

Grid: (B*H, S/bq, S/bk); q/k/v laid out [B*H, S, hd].
Block shapes MXU-aligned: bq/bk multiples of 128 recommended, hd = lane
width multiple (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:                   # pragma: no cover
    pltpu = None

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal block skipping: blocks entirely above the diagonal do nothing
    run = (not causal) or (kj * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32)             # [bq, hd]
        k = k_ref[0].astype(jnp.float32)             # [bk, hd]
        v = v_ref[0].astype(jnp.float32)             # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0)
            cols = kj * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]                           # [bq]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(kj == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q/k/v: [B, H, S, hd] -> [B, H, S, hd]."""
    b, h, s, hd = q.shape
    assert s % bq == 0 and s % bk == 0
    scale = 1.0 / float(hd) ** 0.5
    bh = b * h
    qf = q.reshape(bh, s, hd)
    kf = k.reshape(bh, s, hd)
    vf = v.reshape(bh, s, hd)
    grid = (bh, s // bq, s // bk)

    fn = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, scale=scale,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bhi, qi, kj: (bhi, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda bhi, qi, kj: (bhi, kj, 0)),
            pl.BlockSpec((1, bk, hd), lambda bhi, qi, kj: (bhi, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bhi, qi, kj: (bhi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )
    out = fn(qf, kf, vf)
    return out.reshape(b, h, s, hd)
