"""Pure-jnp oracles for every Pallas kernel (the correctness references).

BSR format used throughout (the TPU-native realization of SparseMap's
compressed formats + Skip mechanism — DESIGN.md §3):

    blocks   : [nnz, bm, bk]   values of nonzero (bm x bk) blocks of P
    col_idx  : [nnz] int32     block-column of each stored block
    row_ptr  : [m_blocks + 1]  CSR-style row pointers over block rows

A two-level structure: (Bitmask | UOP) over block rows + CP over block
columns — i.e. the B/UOP-CP hierarchy of the paper at tile granularity.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------ BSR helpers


def dense_to_bsr(p: np.ndarray, bm: int, bk: int
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Convert dense [M,K] to BSR (drops all-zero blocks)."""
    m, k = p.shape
    assert m % bm == 0 and k % bk == 0
    mb, kb = m // bm, k // bk
    blocks, col_idx, row_ptr = [], [], [0]
    for i in range(mb):
        for j in range(kb):
            blk = p[i * bm:(i + 1) * bm, j * bk:(j + 1) * bk]
            if np.any(blk != 0):
                blocks.append(blk)
                col_idx.append(j)
        row_ptr.append(len(blocks))
    if not blocks:
        blocks = [np.zeros((bm, bk), p.dtype)]
        col_idx = [0]
        row_ptr = [0] + [1] * mb       # degenerate: one padding block
        row_ptr = [0] * (mb + 1)
    return (np.stack(blocks).astype(p.dtype),
            np.asarray(col_idx, np.int32),
            np.asarray(row_ptr, np.int32))


def bsr_to_dense(blocks, col_idx, row_ptr, m_blocks: int, k_blocks: int
                 ) -> np.ndarray:
    bm, bk = blocks.shape[1:]
    out = np.zeros((m_blocks * bm, k_blocks * bk), blocks.dtype)
    for i in range(m_blocks):
        for jj in range(int(row_ptr[i]), int(row_ptr[i + 1])):
            j = int(col_idx[jj])
            out[i * bm:(i + 1) * bm, j * bk:(j + 1) * bk] = blocks[jj]
    return out


# ------------------------------------------------------------ oracles


def bsr_spmm_ref(blocks: jnp.ndarray, col_idx: jnp.ndarray,
                 row_ptr: jnp.ndarray, q: jnp.ndarray,
                 m_blocks: int) -> jnp.ndarray:
    """Z = P @ Q with P in BSR.  Dense reconstruction oracle."""
    bm, bk = blocks.shape[1:]
    k_blocks = q.shape[0] // bk
    p = bsr_to_dense(np.asarray(blocks), np.asarray(col_idx),
                     np.asarray(row_ptr), m_blocks, k_blocks)
    return jnp.asarray(p) @ q


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True) -> jnp.ndarray:
    """q/k/v: [B, H, S, hd] -> [B, H, S, hd]; fp32 softmax."""
    s = q.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def gated_block_spmm_ref(p: jnp.ndarray, q: jnp.ndarray,
                         block_nnz: jnp.ndarray, bm: int, bk: int
                         ) -> jnp.ndarray:
    """Gating oracle: blocks with nnz==0 contribute nothing (the dense
    kernel computes them anyway but predication saves MXU energy —
    numerically identical to a dense matmul with zero blocks)."""
    return p @ q
