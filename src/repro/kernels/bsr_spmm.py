"""BSR SpMM Pallas TPU kernel — SparseMap's Skip mechanism, TPU-native.

SparseMap's *Skip P->compute* locates the next effectual operand via the
leader's metadata and bypasses zero work (paper Fig. 6/14).  Element-
granular skipping does not transfer to a systolic MXU, so the TPU
adaptation is **block-granular compaction** (DESIGN.md §3): the sparse
operand is stored as compacted nonzero (bm x bk) blocks (BSR = UOP over
block rows + CP over block columns, at tile granularity), and a
**scalar-prefetch index map** steers the DMA engine so only effectual
blocks are ever fetched from HBM — the skip saves both energy AND cycles,
exactly the paper's distinction from gating.

Grid: (m_blocks, n_blocks, max_row_nnz).  The k-th step of block-row i
processes stored block ``row_ptr[i] + k``; steps past the row's nnz are
predicated off with ``pl.when`` (they re-fetch the last block of the row
— the index map clamps — but never touch the MXU or the output).

Block shapes must be MXU-aligned: bm, bk, bn multiples of (8, 128) tiles;
matmul dims multiples of 128 give full MXU utilization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:                                  # TPU backend only
    from jax.experimental.pallas import tpu as pltpu
except ImportError:                   # pragma: no cover
    pltpu = None


def _kernel(row_ptr, col_idx,         # scalar-prefetch operands
            blocks_ref, q_ref, z_ref, *, max_row_nnz: int):
    i = pl.program_id(0)
    k = pl.program_id(2)
    nnz_row = row_ptr[i + 1] - row_ptr[i]

    @pl.when(k == 0)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    @pl.when(k < nnz_row)
    def _accum():
        acc = jnp.dot(blocks_ref[0], q_ref[...],
                      preferred_element_type=jnp.float32)
        z_ref[...] += acc.astype(z_ref.dtype)


def bsr_spmm(blocks: jnp.ndarray, col_idx: jnp.ndarray,
             row_ptr: jnp.ndarray, q: jnp.ndarray, *,
             m_blocks: int, max_row_nnz: int, bn: int = 128,
             interpret: bool = False) -> jnp.ndarray:
    """Z[M,N] = P[M,K] @ Q[K,N] with P in BSR.

    blocks: [nnz, bm, bk]; col_idx: [nnz]; row_ptr: [m_blocks+1];
    q: [K, N].  ``max_row_nnz`` bounds the k-grid (rows with fewer stored
    blocks are predicated off).
    """
    nnz, bm, bk = blocks.shape
    kdim, n = q.shape
    assert n % bn == 0, f"N={n} not divisible by bn={bn}"
    grid = (m_blocks, n // bn, max_row_nnz)

    def blocks_map(i, j, k, row_ptr, col_idx):
        idx = jnp.minimum(row_ptr[i] + k,
                          jnp.maximum(row_ptr[i + 1] - 1, 0))
        return (jnp.clip(idx, 0, nnz - 1), 0, 0)

    def q_map(i, j, k, row_ptr, col_idx):
        idx = jnp.minimum(row_ptr[i] + k,
                          jnp.maximum(row_ptr[i + 1] - 1, 0))
        return (col_idx[jnp.clip(idx, 0, nnz - 1)], j)

    def z_map(i, j, k, row_ptr, col_idx):
        return (i, j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), blocks_map),
            pl.BlockSpec((bk, bn), q_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), z_map),
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, max_row_nnz=max_row_nnz),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_blocks * bm, n), q.dtype),
        interpret=interpret,
    )
    return fn(row_ptr, col_idx, blocks, q)
