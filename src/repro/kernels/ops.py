"""Jit'd public wrappers for the Pallas kernels with backend dispatch.

On TPU the real kernels run; elsewhere (this CPU container) they execute
in interpret mode when ``force_interpret`` / REPRO_PALLAS_INTERPRET is
set, else fall back to the jnp reference (the dry-run lowers pure-jnp
models — Pallas TPU kernels cannot lower on the CPU backend).
"""
from __future__ import annotations

import functools
import os

import jax

from . import ref as ref_lib
from .bsr_spmm import bsr_spmm as _bsr_spmm
from .flash_attention import flash_attention as _flash


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:       # pragma: no cover
        return False


def _interpret_flag() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1"


@functools.partial(jax.jit, static_argnames=("m_blocks", "max_row_nnz",
                                             "bn", "mode"))
def bsr_spmm(blocks, col_idx, row_ptr, q, *, m_blocks: int,
             max_row_nnz: int, bn: int = 128, mode: str = "auto"):
    """Z = P @ Q, P in BSR (see kernels.ref for the format).

    mode: "auto" (kernel on TPU, reference elsewhere), "kernel",
    "interpret", "ref".
    """
    if mode == "ref" or (mode == "auto" and not _on_tpu()
                         and not _interpret_flag()):
        return ref_lib.bsr_spmm_ref(blocks, col_idx, row_ptr, q, m_blocks)
    interpret = (mode == "interpret") or (mode == "auto" and not _on_tpu())
    return _bsr_spmm(blocks, col_idx, row_ptr, q, m_blocks=m_blocks,
                     max_row_nnz=max_row_nnz, bn=bn, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "mode"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, mode: str = "auto"):
    """Blocked causal attention [B,H,S,hd]."""
    if mode == "ref" or (mode == "auto" and not _on_tpu()
                         and not _interpret_flag()):
        return ref_lib.flash_attention_ref(q, k, v, causal=causal)
    interpret = (mode == "interpret") or (mode == "auto" and not _on_tpu())
    return _flash(q, k, v, causal=causal, bq=bq, bk=bk,
                  interpret=interpret)
