"""Multi-device self-test, run in a subprocess with forced host devices
(tests/test_distributed.py): exercises pipeline parallelism, compressed
all-reduce, sharded train-step equivalence, and elastic checkpoint
restore onto a different mesh.  Prints "SELFTEST OK" on success.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map


def test_pipeline():
    from repro.distributed.pipeline import pipeline_apply
    mesh = Mesh(np.asarray(jax.devices()[:4]), ("pipe",))
    n_stages, n_micro, mb, d = 4, 8, 2, 16
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((n_stages, d, d)) * 0.3,
                    jnp.float32)
    x = jnp.asarray(rng.standard_normal((n_micro, mb, d)), jnp.float32)

    def stage(wi, h):
        return jnp.tanh(h @ wi)

    with mesh:
        y = pipeline_apply(stage, w, x, mesh, axis="pipe")
    # sequential reference
    ref = x
    for s in range(n_stages):
        ref = jnp.tanh(ref @ w[s])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print("pipeline ok")


def test_compressed_psum():
    from repro.optim.compression import compressed_psum
    mesh = Mesh(np.asarray(jax.devices()[:8]), ("data",))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)

    def f(xl):
        return compressed_psum(xl, "data")

    with mesh:
        y = shard_map(f, mesh=mesh, in_specs=P("data"),
                      out_specs=P("data"))(x)
    exact = x.sum(axis=0, keepdims=True)
    got = np.asarray(y)[0:1]
    rel = np.abs(got - np.asarray(exact)).max() / \
        np.abs(np.asarray(exact)).max()
    assert rel < 0.02, f"int8 psum rel err {rel}"
    print(f"compressed_psum ok (rel err {rel:.4f})")


def test_sharded_train_step_matches_single():
    """Sharded train step == single-device train step (same batch)."""
    from repro.configs import smoke_config
    from repro.models import sharding as shard_ctx
    from repro.models.model import Model
    from repro.optim import optimizer as opt
    from repro.launch.steps import build_train_step

    cfg = smoke_config("mistral-nemo-12b")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    ocfg = opt.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    ostate = opt.init(params, ocfg)
    rng = np.random.default_rng(2)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                              jnp.int32)}

    step = build_train_step(m, ocfg)
    p1, o1, m1 = jax.jit(step)(params, ostate, batch)

    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                ("data", "model"))
    shard_ctx.set_batch_axes(("data",))
    try:
        pspecs = m.param_specs()
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                           is_leaf=lambda x: isinstance(x, P))
        bsh = {k: NamedSharding(mesh, P("data", None))
               for k in batch}
        with mesh:
            params_s = jax.device_put(params, psh)
            batch_s = jax.device_put(batch, bsh)
            p2, o2, m2 = jax.jit(step)(params_s, ostate, batch_s)
    finally:
        shard_ctx.set_batch_axes(None)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-2)
    # parameters close after one update
    l1 = jax.tree.leaves(p1)
    l2 = jax.tree.leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=0.1, atol=0.05)
    print(f"sharded train step ok (loss {float(m1['loss']):.4f} vs "
          f"{float(m2['loss']):.4f})")


def test_elastic_restore():
    """Checkpoint on a (2,4) mesh, restore onto (1,4) (mesh shrink)."""
    from repro.checkpoint import checkpoint as ckpt
    from repro.runtime.fault_tolerance import ElasticPlan

    rng = np.random.default_rng(3)
    tree = {"w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((16,)), jnp.float32)}
    mesh_a = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                  ("data", "model"))
    sh_a = {"w": NamedSharding(mesh_a, P("data", "model")),
            "b": NamedSharding(mesh_a, P("model"))}
    tree_a = jax.device_put(tree, sh_a)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, tree_a)
        assert ckpt.latest_step(d) == 7
        mesh_b = Mesh(np.asarray(jax.devices()[:4]).reshape(1, 4),
                      ("data", "model"))
        sh_b = {"w": NamedSharding(mesh_b, P("data", "model")),
                "b": NamedSharding(mesh_b, P("model"))}
        restored = ckpt.restore(d, 7, tree, shardings=sh_b)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        plan = ElasticPlan.plan(n_devices=4, model_parallel=4)
        assert plan.data_parallel == 1
    print("elastic restore ok")


if __name__ == "__main__":
    test_pipeline()
    test_compressed_psum()
    test_sharded_train_step_matches_single()
    test_elastic_restore()
    print("SELFTEST OK")
