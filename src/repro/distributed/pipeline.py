"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Each device along the ``pipe`` mesh axis holds ONE stage's weights; micro-
batches stream through the stages with ``jax.lax.ppermute`` hops — the
standard JAX-native pipeline (MaxText-style), usable as an outer level on
top of the (data, model) mesh for cross-pod scaling where DP bandwidth is
the constraint.

The schedule is the classic GPipe fill-drain: T = n_micro + n_stages - 1
ticks; device s computes microbatch m at tick t = m + s.  Bubble fraction
= (n_stages-1)/T, so callers should use n_micro >> n_stages.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map


def pipeline_apply(stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
                   stage_params: Any, x: jnp.ndarray, mesh: Mesh,
                   axis: str = "pipe") -> jnp.ndarray:
    """Run ``x`` through ``n_stages`` pipelined applications of
    ``stage_fn``.

    stage_params: pytree with leading axis n_stages (sharded over
    ``axis``); x: [n_micro, mb, ...] microbatched input (replicated).
    Returns [n_micro, mb, ...] outputs of the LAST stage.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    T = n_micro + n_stages - 1

    pspec = jax.tree.map(lambda _: P(axis), stage_params)

    def body(params, xs):
        params = jax.tree.map(lambda t: t[0], params)   # local stage
        stage = jax.lax.axis_index(axis)
        carry = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(t, state):
            carry, outs = state
            m_in = t                        # microbatch entering stage 0
            feed = xs[jnp.clip(m_in, 0, n_micro - 1)]
            inp = jnp.where(stage == 0, feed, carry)
            out = stage_fn(params, inp)
            # last stage writes its finished microbatch m = t - (S-1)
            m_out = t - (n_stages - 1)
            valid = (m_out >= 0) & (m_out < n_micro)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, jnp.where(stage == n_stages - 1, out,
                                 o[jnp.clip(m_out, 0, n_micro - 1)]),
                    jnp.clip(m_out, 0, n_micro - 1), 0),
                lambda o: o, outs)
            carry = jax.lax.ppermute(out, axis, perm)
            return carry, outs

        carry, outs = jax.lax.fori_loop(0, T, tick, (carry, outs))
        # gather the last stage's outputs to all pipeline ranks
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    fn = shard_map(body, mesh=mesh,
                   in_specs=(pspec, P()), out_specs=P(),
                   check_vma=False)
    return fn(stage_params, x)
