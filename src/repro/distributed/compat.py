"""JAX version-compat shim for ``shard_map``.

``from jax import shard_map`` only exists on newer JAX; on 0.4.x the
implementation lives in ``jax.experimental.shard_map``.  The replication-
check keyword was also renamed (``check_rep`` -> ``check_vma``) along the
way.  This module exposes one :func:`shard_map` with the NEW surface
(keyword-only ``mesh/in_specs/out_specs/check_vma``) and translates to
whatever the installed JAX accepts.  See COMPAT.md.
"""
from __future__ import annotations

import inspect

try:                                    # JAX >= 0.6: public API
    from jax import shard_map as _shard_map
except ImportError:                     # JAX 0.4.x/0.5.x: experimental
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    kw = {}
    if check_vma is not None:
        if "check_vma" in _PARAMS:
            kw["check_vma"] = check_vma
        elif "check_rep" in _PARAMS:
            kw["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)
